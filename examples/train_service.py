"""Crash-safe DP training service demo: start the daemon, kill -9 it
mid-step via deterministic fault injection, resume, and show that the
persistent privacy ledger and checkpoint survive with the budget enforced.

Three acts, all on the tiny CPU arch (a couple of minutes total):

  1. launch the service with `--fault-at post-ledger-append:5` — the
     process os._exit()s the instant step 5's spend hits the ledger,
     before the gradient update commits (the worst-ordered crash),
  2. relaunch with no fault: the service replays the ledger through the
     RDP accountant, falls back to the newest *verified* checkpoint, and
     finishes the run bitwise-identically to a never-crashed one,
  3. read the ledger back and print the per-step epsilon trajectory plus
     the final spend.

    PYTHONPATH=src python examples/train_service.py [--service-dir DIR]
"""
import argparse
import os
import shutil
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
SERVICE_ARGS = [
    "--arch", "tiny", "--steps", "10", "--batch", "8", "--seq", "32",
    "--docs", "64", "--sigma", "0.8", "--checkpoint-every", "3",
    "--budget-eps", "6.0", "--log-every", "2",
]


def launch(service_dir, extra=()):
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO}/src" + (
        f":{env['PYTHONPATH']}" if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "repro.launch.service",
           "--service-dir", service_dir] + SERVICE_ARGS + list(extra)
    return subprocess.run(cmd, env=env).returncode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--service-dir", default="/tmp/repro_service_demo")
    args = ap.parse_args()
    shutil.rmtree(args.service_dir, ignore_errors=True)

    from repro.launch.service import EXIT_FAULT, PrivacyLedger
    from repro.core.accounting import RdpAccountant

    print("=== act 1: service killed at post-ledger-append:5 ===")
    rc = launch(args.service_dir, ["--fault-at", "post-ledger-append:5"])
    assert rc == EXIT_FAULT, f"expected fault exit {EXIT_FAULT}, got {rc}"
    print(f"(process died with exit code {rc}: the step-5 spend is on disk, "
          "the step-5 update is not)")

    print("\n=== act 2: resume — ledger replayed, no double-spend ===")
    rc = launch(args.service_dir)
    assert rc == 0, f"resume failed with exit code {rc}"

    print("\n=== act 3: the ledger, replayed ===")
    records = PrivacyLedger(
        os.path.join(args.service_dir, "ledger.jsonl")).replay()
    acct = RdpAccountant()
    for rec in records:
        acct.spend(rec["q"], rec["sigma"])
        print(f"  step {rec['step']:2d}  q={rec['q']:.5f} "
              f"sigma={rec['sigma']:.4f}  eps={acct.epsilon(1e-5):.4f}")
    print(f"final spend: epsilon={acct.epsilon(1e-5):.4f} over "
          f"{acct.steps} ledgered steps (budget 6.0)")


if __name__ == "__main__":
    main()
