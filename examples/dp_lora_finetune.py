"""DP LoRA fine-tuning — the paper's GPT-3-at-175B recipe (Sec 5.3) at
laptop scale: freeze the base model, train adapters on the attention
projections with per-layer clipping, then MERGE the adapters for serving.

    PYTHONPATH=src python examples/dp_lora_finetune.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.core.dp_sgd import DPConfig, make_dp_train_step
from repro.core.lora import merge_lora
from repro.core.spec import init_params
from repro.data import SyntheticLM, pack_documents, make_lm_batch, PoissonSampler
from repro.models.transformer import build_model

# deepseek-v3's reduced variant: MLA attention + MoE — the family the
# paper-scale run uses with per-device clipping. lora_rank turns on DP-LoRA.
cfg = dataclasses.replace(get_config("deepseek-v3-671b", reduced=True),
                          lora_rank=8)
model = build_model(cfg)
assert model.trainable_key == "lora"
params = init_params(model.spec, jax.random.PRNGKey(0))
n_lora = sum(int(np.prod(l.shape)) for l in
             jax.tree_util.tree_leaves(params["lora"]))
print(f"base params: {model.num_params - n_lora:,} (frozen)   "
      f"LoRA params: {n_lora:,} (trained, K={model.layout.num_groups} groups)")

src = SyntheticLM(vocab_size=cfg.vocab_size, num_docs=96, doc_len=96)
rows = pack_documents(src.documents(), seq_len=48)
BATCH, STEPS = 8, 40
sampler = PoissonSampler(rows.shape[0], BATCH / rows.shape[0], BATCH)

# equal-budget noise allocation: each group's noise is independent of the
# other groups' thresholds — the per-device scheme (paper Sec 4).
dp = DPConfig(mode="per_layer", epsilon=4.0, delta=1e-5,
              sampling_rate=BATCH / rows.shape[0], steps=STEPS,
              adaptive=True, noise_strategy="equal_budget",
              init_threshold=1e-2)
init_fn, step_fn, plan = make_dp_train_step(
    model.loss_fn, model.dp_spec, model.layout, optim.adam(5e-3), dp,
    batch_size=BATCH, trainable_key="lora")
opt_state, dp_state = init_fn(params)
step = jax.jit(step_fn)
for i in range(STEPS):
    batch = make_lm_batch(rows, sampler.next_indices(), BATCH)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    params, opt_state, dp_state, m = step(params, opt_state, dp_state,
                                          batch, jax.random.PRNGKey(2))
    if i % 10 == 0 or i == STEPS - 1:
        print(f"step {i:3d}  loss {float(m.loss):.3f}  "
              f"clip_frac {float(m.clip_fraction):.2f}")

# Merge adapters into the frozen weights for serving (per run, offline).
name = "moe_blocks" if "moe_blocks" in params["lora"] else "dense_blocks"
site = params["lora"][name]["o"]
w = params[name]["attn"]["o"]["w"]
merged = jax.vmap(lambda w_, a_, b_: merge_lora(w_, a_, b_, cfg.lora_alpha)
                  )(w, site["a"], site["b"])
print("merged adapter into", name, "o-proj:",
      bool(not np.allclose(np.asarray(merged), np.asarray(w))))
