"""Quickstart: differentially private training with adaptive per-layer
clipping (the paper's Algorithm 1) in ~40 lines of user code.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import get_config
from repro.core.accounting import compute_epsilon
from repro.core.dp_sgd import DPConfig, make_dp_train_step
from repro.core.spec import init_params
from repro.data import PoissonSampler, SyntheticLM, make_lm_batch, pack_documents
from repro.models.transformer import build_model

# 1. A model. Any assigned architecture works ("qwen3-4b", "rwkv6-7b", ...);
#    reduced=True gives the CPU-sized variant of the same family.
cfg = get_config("qwen3-4b", reduced=True)
model = build_model(cfg)
params = init_params(model.spec, jax.random.PRNGKey(0))
print(f"model: {cfg.name}  params={model.num_params:,}  "
      f"clipping groups K={model.layout.num_groups}")

# 2. Data with POISSON subsampling (what the accountant assumes).
src = SyntheticLM(vocab_size=cfg.vocab_size, num_docs=128, doc_len=128)
rows = pack_documents(src.documents(), seq_len=64)
BATCH, STEPS = 16, 60
sampler = PoissonSampler(num_examples=rows.shape[0],
                         rate=BATCH / rows.shape[0], max_batch=BATCH)

# 3. The DP recipe: adaptive per-layer clipping, eps=8, 1% of budget spent
#    on private quantile estimation (paper Sec 3.3).
dp = DPConfig(mode="per_layer", epsilon=8.0, delta=1e-5,
              sampling_rate=BATCH / rows.shape[0], steps=STEPS,
              adaptive=True, target_quantile=0.5,
              quantile_budget_fraction=0.01)
init_fn, step_fn, plan = make_dp_train_step(
    model.loss_fn, model.spec, model.layout, optim.adam(1e-3), dp,
    batch_size=BATCH)
opt_state, dp_state = init_fn(params)
step = jax.jit(step_fn)
print(f"sigma={plan.sigma:.3f} -> sigma_new={plan.sigma_new:.3f} "
      f"(Prop 3.1 split, sigma_b={plan.sigma_b:.1f})")

# 4. Train.
key = jax.random.PRNGKey(1)
for i in range(STEPS):
    batch = make_lm_batch(rows, sampler.next_indices(), BATCH)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    params, opt_state, dp_state, m = step(params, opt_state, dp_state,
                                          batch, key)
    if i % 10 == 0 or i == STEPS - 1:
        print(f"step {i:3d}  loss {float(m.loss):.3f}  "
              f"clip_frac {float(m.clip_fraction):.2f}  "
              f"mean C_k {float(m.mean_threshold):.3f}")

eps = compute_epsilon(sigma=plan.sigma, sampling_rate=dp.sampling_rate,
                      steps=STEPS, delta=dp.delta)
print(f"privacy spent: eps={eps:.2f} at delta={dp.delta}")
