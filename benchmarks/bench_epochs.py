"""Table 4/12 analogue: adaptive per-layer vs flat under fixed epochs.

Paper: under the SAME number of training epochs, adaptive per-layer
clipping matches flat clipping's utility — which, combined with the per-
update speed advantage (bench_throughput), yields the wall-time win.
Testbed: tiny LM on the synthetic Markov corpus, loss after E epochs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line
from repro import optim
from repro.configs import get_config
from repro.core.dp_sgd import DPConfig, make_dp_train_step
from repro.core.spec import init_params
from repro.data import PoissonSampler, SyntheticLM, make_lm_batch, pack_documents
from repro.models.transformer import build_model


def _train(mode, epochs, seed, *, quick):
    cfg = get_config("tiny")
    m = build_model(cfg)
    seq, batch = 32, 16
    src = SyntheticLM(vocab_size=cfg.vocab_size, num_docs=96, doc_len=64,
                      seed=7)
    rows = pack_documents(src.documents(), seq)
    n = rows.shape[0]
    steps = max(1, epochs * n // batch)
    params = init_params(m.spec, jax.random.PRNGKey(seed))
    dpc = DPConfig(mode=mode, sigma=0.7, sampling_rate=batch / n,
                   steps=steps, adaptive=(mode == "per_layer"),
                   init_threshold=1.0, target_quantile=0.5)
    init_fn, step_fn, _ = make_dp_train_step(
        m.loss_fn, m.spec, m.layout, optim.adam(2e-3), dpc, batch_size=batch)
    opt_state, dp_state = init_fn(params)
    step = jax.jit(step_fn)
    sampler = PoissonSampler(num_examples=n, rate=batch / n,
                             max_batch=batch, seed=seed)
    key = jax.random.PRNGKey(seed)
    loss = None
    for i in range(steps):
        idx = sampler.next_indices()
        b = make_lm_batch(rows, idx, batch)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, dp_state, met = step(params, opt_state, dp_state,
                                                b, key)
    # eval: mean loss on all rows
    th = m.layout.pack_value(jnp.inf, n)
    ev = make_lm_batch(rows, np.arange(n), n)
    losses = m.loss_fn(params, {k: jnp.asarray(v) for k, v in ev.items()}, th)
    return float(jnp.mean(losses))


def run(quick: bool = True) -> list[str]:
    epoch_grid = (1, 3) if quick else (1, 3, 10)
    seeds = (0,) if quick else (0, 1, 2)
    lines = []
    for e in epoch_grid:
        for mode, label in (("per_layer", "adaptive_per_layer"),
                            ("ghost_flat", "flat")):
            ls = [_train(mode, e, s, quick=quick) for s in seeds]
            lines.append(csv_line(
                f"table4_epochs_E{e}_{label}", 0.0,
                f"eval_loss={np.mean(ls):.4f}"))
    return lines
