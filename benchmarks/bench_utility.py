"""Tables 1/11 + Figure 3 analogue: utility of clipping schemes at fixed eps.

Paper claims to reproduce qualitatively (synthetic-classification testbed,
the offline stand-in for WRN16-4/CIFAR-10; 3 seeds):
  (1) FIXED per-layer clipping underperforms FIXED flat clipping,
  (2) ADAPTIVE per-layer clipping recovers the gap (matches flat),
  (3) adaptivity helps flat clipping only marginally (Table 11).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, mlp_classifier, timeit
from repro import optim
from repro.core.dp_sgd import DPConfig, make_dp_train_step
from repro.core.spec import init_params
from repro.data import SyntheticClassification


def _train_once(mode, adaptive, seed, *, sigma, steps, batch, lr,
                init_threshold, quick):
    dim, classes = 32, 10
    # feature scales create the Fig-2 regime: per-layer grad norms differ by
    # orders of magnitude, so a uniform C/sqrt(K) per-layer split over-clips
    # the large-gradient layers and drowns the small ones in noise
    spec, layout, loss_fn, accuracy = mlp_classifier(
        dim, 64, 2, classes, feature_scales=(6.0, 1.0, 0.15))
    data = SyntheticClassification(num_classes=classes, dim=dim,
                                   num_examples=2048, noise=0.9, seed=123)
    x_all, y_all = data.arrays()
    x_tr, y_tr = x_all[:1536], y_all[:1536]
    x_te, y_te = x_all[1536:], y_all[1536:]
    params = init_params(spec, jax.random.PRNGKey(seed))
    # per-layer FIXED: C_k = C/sqrt(K) (paper's Appendix A.1 protocol)
    k = layout.num_groups
    init_c = init_threshold / np.sqrt(k) if mode == "per_layer" and not adaptive \
        else init_threshold
    dpc = DPConfig(mode=mode, sigma=sigma, sampling_rate=batch / 1536,
                   steps=steps, adaptive=adaptive, init_threshold=init_c,
                   target_quantile=0.6, quantile_budget_fraction=0.01,
                   # Appendix A.1: adaptive thresholds rescaled to the same
                   # equivalent global C as the fixed baselines
                   threshold_rescale=init_threshold if adaptive else None)
    init_fn, step_fn, _ = make_dp_train_step(
        loss_fn, spec, layout, optim.sgd(lr, momentum=0.5), dpc,
        batch_size=batch)
    opt_state, dp_state = init_fn(params)
    step = jax.jit(step_fn)
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed + 100)
    for i in range(steps):
        idx = rng.random(1536) < batch / 1536
        sel = np.nonzero(idx)[0][:batch]
        xb = np.zeros((batch, dim), np.float32)
        yb = np.zeros((batch,), np.int32)
        xb[:len(sel)] = x_tr[sel]
        yb[:len(sel)] = y_tr[sel]
        yb[len(sel):] = 0
        # padding rows: zero inputs w/ label 0 contribute a constant grad —
        # mask by replicating DP convention: zero them via targets trick is
        # not available for the MLP; instead subsample exactly
        xb = jnp.asarray(x_tr[sel]) if len(sel) else jnp.zeros((1, dim))
        yb = jnp.asarray(y_tr[sel]) if len(sel) else jnp.zeros((1,), jnp.int32)
        if len(sel) == 0:
            continue
        if len(sel) != batch:
            # pad by repeating (acceptable in the benchmark; the exact DP
            # pipeline lives in repro.data and is tested separately)
            reps = np.resize(sel, batch)
            xb, yb = jnp.asarray(x_tr[reps]), jnp.asarray(y_tr[reps])
        params, opt_state, dp_state, met = step(
            params, opt_state, dp_state, (xb, yb), key)
    return accuracy(params, jnp.asarray(x_te), jnp.asarray(y_te))


def run(quick: bool = True) -> list[str]:
    seeds = (0, 1, 2)
    steps = 200 if quick else 400
    settings = [
        ("fixed_flat", "ghost_flat", False),
        ("fixed_per_layer", "per_layer", False),
        ("adaptive_per_layer", "per_layer", True),
        ("adaptive_flat", "ghost_flat", True),
    ]
    lines = []
    results = {}
    lr_grid = (0.25, 0.5, 1.0)  # paper protocol: lr tuned per method
    for name, mode, adaptive in settings:
        best, best_lr = -1.0, None
        for lr in lr_grid:
            accs = [
                _train_once(mode, adaptive, s, sigma=0.8, steps=steps,
                            batch=128, lr=lr, init_threshold=1.0,
                            quick=quick)
                for s in seeds
            ]
            if np.mean(accs) > best:
                best, best_lr, best_std = float(np.mean(accs)), lr,                     float(np.std(accs))
        results[name] = best
        lines.append(csv_line(
            f"table1_utility_{name}", 0.0,
            f"val_acc={best:.4f};std={best_std:.4f};lr={best_lr}"))
    # paper-claim checks (qualitative ordering)
    ok1 = results["fixed_per_layer"] <= results["fixed_flat"] + 0.03
    ok2 = results["adaptive_per_layer"] >= results["fixed_per_layer"] - 0.03
    ok3 = results["adaptive_per_layer"] >= results["fixed_flat"] - 0.05
    lines.append(csv_line(
        "table1_claims", 0.0,
        f"fixed_pl_le_flat={ok1};adaptive_recovers={ok2};"
        f"adaptive_matches_flat={ok3}"))
    return lines
