"""Table 6 / Sec 4 analogue: per-device clipping has no extra communication.

The paper's Sec-4 argument is about COMMUNICATION: flat clipping must move
per-example norm information across the devices holding model pieces;
per-device clipping must not. On TPU we measure exactly this from the
partitioned HLO of the production-mesh dry-run:

  collective bytes/step of the train step under
    ghost_flat   (global norms; the communication-heavy scheme)
    per_layer    (per-layer norms: one small psum per layer)
    per_shard    (per-device analogue: blocked groups, norm reductions
                  stay shard-local)

Reads cached dry-run artifacts when available; lowers fresh ones otherwise
(slow: ~1 min per variant). Also reports DP-LoRA vs full-model clipped
bytes (the paper's GPT-3 recipe).
"""
from __future__ import annotations

import json
import os

from benchmarks.common import csv_line

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def _load_or_run(arch, shape, mesh_kind, clipping):
    suffix = "" if clipping == "per_layer" else f"__{clipping}"
    fn = os.path.join(RESULTS, f"{arch}__{shape}__{mesh_kind}{suffix}.json")
    if os.path.exists(fn):
        with open(fn) as f:
            r = json.load(f)
        if r.get("status") == "ok":
            return r
    from repro.launch.dryrun import run_one
    return run_one(arch, shape, mesh_kind, clipping=clipping)


def run(quick: bool = True) -> list[str]:
    lines = []
    arch, shape = "qwen3-4b", "train_4k"
    rows = {}
    for clipping in ("per_layer", "ghost_flat", "per_shard"):
        r = _load_or_run(arch, shape, "single", clipping)
        if r.get("status") != "ok":
            lines.append(csv_line(f"table6_comm_{clipping}", 0.0,
                                  f"status={r.get('status')}"))
            continue
        coll = r["collectives"]["total_bytes"]
        rows[clipping] = coll
        lines.append(csv_line(
            f"table6_comm_{clipping}", 0.0,
            f"collective_GiB_per_step={coll/2**30:.2f};"
            f"flops={r['flops']:.3e}"))
    if "ghost_flat" in rows and "per_shard" in rows:
        lines.append(csv_line(
            "table6_comm_claim", 0.0,
            f"per_shard_vs_flat_bytes_ratio="
            f"{rows['per_shard']/max(rows['ghost_flat'],1):.3f}"))
    return lines
