"""Figure 1 analogue: per-update efficiency of clipping implementations.

Paper claim: fused per-layer clipping is as memory-efficient and almost as
fast per update as NON-PRIVATE training, while usual (Opacus-style
materializing) flat clipping pays O(B x params) memory and ghost clipping
pays a second backward pass. The book-keeping engine (repro.core.bk)
removes that second pass: `ghost_flat`/`per_group` run under BOTH
executions here so the win is measured, not assumed —
`benchmarks/BENCH_throughput.json` records the bk:twopass step-time ratio
across PRs.

CPU measurement at GPT-2-small-like slice (scaled down): we report
us/step and the throughput RATIO vs non-private — the paper's Figure-1
quantity. (Absolute CPU times are not TPU times; ratios transfer because
every variant runs the same XLA stack.)
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax

from benchmarks.common import csv_line, timeit, topology
from repro import optim
from repro.configs import get_config
from repro.core.dp_sgd import DPConfig, make_dp_train_step
from repro.core.spec import init_params
from repro.launch.inputs import concrete_train_batch
from repro.models.transformer import build_model

_OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_throughput.json")

# (label, mode, execution); executions only differ for the flat/group modes
VARIANTS = (
    ("non_private", "non_private", "bk"),
    ("per_layer", "per_layer", "bk"),
    ("ghost_flat_bk", "ghost_flat", "bk"),
    ("ghost_flat_twopass", "ghost_flat", "twopass"),
    ("per_group_bk", "per_group", "bk"),
    ("per_group_twopass", "per_group", "twopass"),
    ("naive_flat", "naive_flat", "bk"),
)


def run(quick: bool = True) -> list[str]:
    cfg = get_config("tiny")
    cfg = dataclasses.replace(cfg, num_layers=4, d_model=128, d_ff=512,
                              vocab_size=2048, num_heads=8, num_kv_heads=4)
    m = build_model(cfg)
    params = init_params(m.spec, jax.random.PRNGKey(0))
    # t=256+ is the regime the paper's Figure 1 targets: the backward chain
    # (what BK's single pass saves) dominates the per-step cost there, while
    # at short T fixed costs (norms, epilogue) mask the second-pass saving
    b, t = (8, 256) if quick else (16, 512)
    batch = concrete_train_batch(cfg, b, t, jax.random.PRNGKey(1))
    lines = []
    records = []
    times: dict[str, float] = {}
    base_us = None
    for label, mode, execution in VARIANTS:
        assign = (tuple(i % 2 for i in range(m.layout.num_groups))
                  if mode == "per_group" else None)
        dpc = DPConfig(mode=mode, sigma=1.0, sampling_rate=0.01, steps=100,
                       adaptive=(mode == "per_layer"), execution=execution,
                       group_assignment=assign)
        init_fn, step_fn, _ = make_dp_train_step(
            m.loss_fn, m.spec, m.layout, optim.adam(1e-3), dpc,
            batch_size=b)
        opt_state, dp_state = init_fn(params)
        step = jax.jit(step_fn)
        us = timeit(step, params, opt_state, dp_state, batch,
                    jax.random.PRNGKey(2))
        times[label] = us
        if label == "non_private":
            base_us = us
        ratio = us / base_us
        records.append({"name": label, "mode": mode, "execution": execution,
                        "us_per_step": round(us, 1),
                        "ratio_vs_nonprivate": round(ratio, 3)})
        lines.append(csv_line(f"fig1_throughput_{label}", us,
                              f"ratio_vs_nonprivate={ratio:.2f}"))

    for mode in ("ghost_flat", "per_group"):
        r = times[f"{mode}_bk"] / times[f"{mode}_twopass"]
        records.append({"name": f"{mode}_bk_vs_twopass", "mode": mode,
                        "ratio_bk_vs_twopass": round(r, 3)})
        lines.append(csv_line(f"fig1_{mode}_bk_vs_twopass",
                              times[f"{mode}_bk"],
                              f"ratio_bk_vs_twopass={r:.2f}"))

    payload = {
        "topology": topology(),
        "unix_time": int(time.time()),
        "quick": quick,
        "batch": b, "seq": t,
        "records": records,
    }
    data: dict = {"runs": {}}
    if os.path.exists(_OUT_PATH):
        try:
            prev = json.load(open(_OUT_PATH))
            if isinstance(prev.get("runs"), dict):
                data = prev
        except (OSError, ValueError):
            pass
    data["runs"]["quick" if quick else "full"] = payload
    with open(_OUT_PATH, "w") as fh:
        json.dump(data, fh, indent=1)
    lines.append(csv_line("throughput_bench_json_written", 0.0, _OUT_PATH))
    return lines


if __name__ == "__main__":
    for line in run(quick=True):
        print(line, flush=True)
