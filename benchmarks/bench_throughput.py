"""Figure 1 analogue: per-update efficiency of clipping implementations.

Paper claim: fused per-layer clipping is as memory-efficient and almost as
fast per update as NON-PRIVATE training, while usual (Opacus-style
materializing) flat clipping pays O(B x params) memory and ghost clipping
pays a second backward pass.

CPU measurement at GPT-2-small-like slice (scaled down): we report
us/step and the throughput RATIO vs non-private — the paper's Figure-1
quantity. (Absolute CPU times are not TPU times; ratios transfer because
every variant runs the same XLA stack.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line, timeit
from repro import optim
from repro.configs import get_config
from repro.core.dp_sgd import DPConfig, make_dp_train_step
from repro.core.spec import init_params
from repro.launch.inputs import concrete_train_batch
from repro.models.transformer import build_model
import dataclasses


def run(quick: bool = True) -> list[str]:
    cfg = get_config("tiny")
    cfg = dataclasses.replace(cfg, num_layers=4, d_model=128, d_ff=512,
                              vocab_size=2048, num_heads=8, num_kv_heads=4)
    m = build_model(cfg)
    params = init_params(m.spec, jax.random.PRNGKey(0))
    b, t = (8, 128) if quick else (16, 256)
    batch = concrete_train_batch(cfg, b, t, jax.random.PRNGKey(1))
    lines = []
    base_us = None
    for mode in ("non_private", "per_layer", "ghost_flat", "naive_flat"):
        dpc = DPConfig(mode=mode, sigma=1.0, sampling_rate=0.01, steps=100,
                       adaptive=(mode == "per_layer"))
        init_fn, step_fn, _ = make_dp_train_step(
            m.loss_fn, m.spec, m.layout, optim.adam(1e-3), dpc,
            batch_size=b)
        opt_state, dp_state = init_fn(params)
        step = jax.jit(step_fn)
        us = timeit(step, params, opt_state, dp_state, batch,
                    jax.random.PRNGKey(2))
        if mode == "non_private":
            base_us = us
        ratio = us / base_us
        lines.append(csv_line(f"fig1_throughput_{mode}", us,
                              f"ratio_vs_nonprivate={ratio:.2f}"))
    return lines
