"""Shared benchmark harness utilities."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dp_layers as dpl
from repro.core.spec import GroupLayout, P, init_params


def topology() -> dict:
    """Device-topology metadata stamped into every BENCH_*.json record, so
    numbers from different machines / virtual-device configurations are
    never compared blind across PRs. The same stamp keys the on-disk
    autotune table and compile cache (repro.kernels.autotune)."""
    from repro.kernels.autotune import topology_stamp
    return topology_stamp()


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


# ---------------------------------------------------------------------------
# Small models used by the utility benchmarks (CIFAR/SST-2 analogues).
# ---------------------------------------------------------------------------


def mlp_classifier(dim: int, width: int, depth: int, classes: int,
                   feature_scales: tuple[float, ...] | None = None):
    """Spec + per-example-loss fn for a DP MLP classifier.

    feature_scales: optional per-layer input magnification — creates the
    strongly NON-uniform per-layer gradient norms of the paper's Figure 2
    (what makes hand-set uniform per-layer thresholds hurt)."""
    spec = {}
    sizes = [dim] + [width] * depth + [classes]
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        spec[f"l{i}"] = {"w": P((a, b)), "b": P((b,), init="zeros")}
    layout = GroupLayout(spec)

    n_layers = len(sizes) - 1
    scales = feature_scales or (1.0,) * n_layers

    def loss_fn(params, batch, th):
        x, y = batch
        h = x
        for i in range(n_layers):
            h = dpl.dp_linear(params[f"l{i}"]["w"], params[f"l{i}"]["b"],
                              (h * scales[i])[:, None, :] if h.ndim == 2
                              else h * scales[i], th[f"l{i}"])
            h = h[:, 0] if h.ndim == 3 else h
            if i < n_layers - 1:
                h = jnp.tanh(h)
        logp = jax.nn.log_softmax(h)
        return -logp[jnp.arange(y.shape[0]), y]

    def accuracy(params, x, y):
        th = layout.pack_value(jnp.inf, x.shape[0])
        h = x
        for i in range(n_layers):
            h = dpl.dp_linear(params[f"l{i}"]["w"], params[f"l{i}"]["b"],
                              (h * scales[i])[:, None, :],
                              th[f"l{i}"])[:, 0]
            if i < n_layers - 1:
                h = jnp.tanh(h)
        return float(jnp.mean((jnp.argmax(h, -1) == y).astype(jnp.float32)))

    return spec, layout, loss_fn, accuracy
