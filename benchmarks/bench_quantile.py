"""Figures 5/6 + Appendix F analogue: quantile-target and budget ablations.

  * target quantile sweep: validation accuracy is robust across a wide
    range of q (Fig 5),
  * budget fraction r sweep: tiny r suffices for quantile estimation
    (Fig 6 / Andrew et al.), and
  * noise-allocation strategies are comparable, global slightly best
    (Appendix E / Table 10).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line
from benchmarks.bench_utility import _train_once


def run(quick: bool = True) -> list[str]:
    lines = []
    qs = (0.3, 0.6, 0.9) if quick else (0.1, 0.3, 0.5, 0.6, 0.75, 0.9)
    steps = 120 if quick else 400
    import benchmarks.bench_utility as BU
    import repro.core.dp_sgd as D

    # target quantile sweep (adaptive per-layer)
    for q in qs:
        import functools
        from repro import optim
        from repro.core.dp_sgd import DPConfig, make_dp_train_step
        from repro.core.spec import init_params
        from repro.data import SyntheticClassification
        import jax, jax.numpy as jnp
        from benchmarks.common import mlp_classifier
        spec, layout, loss_fn, accuracy = mlp_classifier(32, 64, 2, 10)
        data = SyntheticClassification(num_classes=10, dim=32,
                                       num_examples=2048, noise=0.9, seed=123)
        x_all, y_all = data.arrays()
        x_tr, y_tr = x_all[:1536], y_all[:1536]
        x_te, y_te = x_all[1536:], y_all[1536:]
        params = init_params(spec, jax.random.PRNGKey(0))
        dpc = DPConfig(mode="per_layer", sigma=0.8, sampling_rate=128 / 1536,
                       steps=steps, adaptive=True, init_threshold=1.0,
                       target_quantile=q)
        init_fn, step_fn, _ = make_dp_train_step(
            loss_fn, spec, layout, optim.sgd(0.5, momentum=0.5), dpc,
            batch_size=128)
        opt_state, dp_state = init_fn(params)
        step = jax.jit(step_fn)
        rng = np.random.default_rng(0)
        for i in range(steps):
            sel = rng.choice(1536, 128, replace=False)
            params, opt_state, dp_state, _ = step(
                params, opt_state, dp_state,
                (jnp.asarray(x_tr[sel]), jnp.asarray(y_tr[sel])),
                jax.random.PRNGKey(i))
        acc = accuracy(params, jnp.asarray(x_te), jnp.asarray(y_te))
        lines.append(csv_line(f"fig5_quantile_q{q}", 0.0,
                              f"val_acc={acc:.4f}"))

    # noise allocation strategies (Appendix E)
    for strategy in ("global", "equal_budget", "weighted"):
        import jax, jax.numpy as jnp
        from repro import optim
        from repro.core.dp_sgd import DPConfig, make_dp_train_step
        from repro.core.spec import init_params
        from repro.data import SyntheticClassification
        from benchmarks.common import mlp_classifier
        spec, layout, loss_fn, accuracy = mlp_classifier(32, 64, 2, 10)
        data = SyntheticClassification(num_classes=10, dim=32,
                                       num_examples=2048, noise=0.9, seed=123)
        x_all, y_all = data.arrays()
        x_tr, y_tr = x_all[:1536], y_all[:1536]
        x_te, y_te = x_all[1536:], y_all[1536:]
        params = init_params(spec, jax.random.PRNGKey(0))
        dpc = DPConfig(mode="per_layer", sigma=0.8, sampling_rate=128 / 1536,
                       steps=steps, adaptive=True, init_threshold=1.0,
                       target_quantile=0.6, noise_strategy=strategy)
        init_fn, step_fn, _ = make_dp_train_step(
            loss_fn, spec, layout, optim.sgd(0.5, momentum=0.5), dpc,
            batch_size=128)
        opt_state, dp_state = init_fn(params)
        step = jax.jit(step_fn)
        rng = np.random.default_rng(0)
        for i in range(steps):
            sel = rng.choice(1536, 128, replace=False)
            params, opt_state, dp_state, _ = step(
                params, opt_state, dp_state,
                (jnp.asarray(x_tr[sel]), jnp.asarray(y_tr[sel])),
                jax.random.PRNGKey(i))
        import jax.numpy as jnp2
        acc = accuracy(params, jnp2.asarray(x_te), jnp2.asarray(y_te))
        lines.append(csv_line(f"table10_alloc_{strategy}", 0.0,
                              f"val_acc={acc:.4f}"))
    return lines
