"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod mesh:
    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw
(the dry-run records are already per-device, loop-trip-count-aware).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16 * 2**30

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

# dense-equivalent / active parameter counts for MODEL_FLOPS = 6·N·D
ACTIVE_FRACTION = {
    # MoE: active params ≈ dense + shared + top_k/E of routed experts
    "granite-moe-3b-a800m": None,  # computed from records below
    "deepseek-v3-671b": None,
}


def model_flops(rec: dict) -> float:
    """6·N·D (dense) or 6·N_active·D (MoE) per DEVICE for train shapes;
    2·N·D for prefill; 2·N_active per token for decode."""
    from repro.configs import get_config
    from repro.models.config import INPUT_SHAPES
    arch, shape_name = rec["arch"], rec["shape"]
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    n = rec["num_params"]
    if cfg.num_experts:
        # subtract inactive expert params
        e, k = cfg.num_experts, cfg.num_experts_per_tok
        expert_params = (cfg.num_layers - cfg.first_k_dense) * e * (
            3 * cfg.d_model * cfg.moe_d_ff)
        n = n - expert_params * (1 - k / e)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens / rec["devices"]


def load_records(mesh: str = "single", clipping_suffix: str = "") -> list[dict]:
    out = []
    if not os.path.isdir(RESULTS):
        return out
    for fn in sorted(os.listdir(RESULTS)):
        if not fn.endswith(f"__{mesh}{clipping_suffix}.json"):
            continue
        if clipping_suffix == "" and fn.count("__") != 2:
            continue
        with open(os.path.join(RESULTS, fn)) as f:
            out.append(json.load(f))
    return out


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "status": rec.get("status"),
                "reason": rec.get("reason", rec.get("error", ""))[:60]}
    t_comp = rec["flops"] / PEAK_FLOPS
    # memory term bounds: XLA-style per-instruction bytes OVERCOUNTS HBM
    # traffic (fused intermediates re-counted per loop iteration); the live
    # working set (args+temp+out) touched once is a LOWER bound. Real HBM
    # time lies in [t_mem_lo, t_mem_hi]; the dominant-term call uses the
    # lower bound (conservative about declaring memory-bound).
    temp = rec["memory"].get("temp_size_in_bytes", 0)
    args = rec["memory"].get("argument_size_in_bytes", 0)
    outs = rec["memory"].get("output_size_in_bytes", 0)
    t_mem_hi = rec["bytes_accessed"] / HBM_BW
    t_mem_lo = (temp + args + outs) / HBM_BW
    t_coll = rec["collectives"]["total_bytes"] / ICI_BW
    dom = max((t_comp, "compute"), (t_mem_lo, "memory"),
              (t_coll, "collective"))[1]
    mf = model_flops(rec)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
        "t_compute_s": t_comp, "t_memory_s": t_mem_lo,
        "t_memory_hi_s": t_mem_hi,
        "t_collective_s": t_coll, "dominant": dom,
        "model_flops": mf, "useful_ratio": mf / max(rec["flops"], 1),
        "temp_gib": temp / 2**30, "args_gib": args / 2**30,
        "fits_hbm": (temp + args) <= HBM_PER_CHIP,
    }


def table(mesh: str = "single") -> list[dict]:
    return [r for r in (roofline_row(rec) for rec in load_records(mesh))
            if r is not None]


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'comp(s)':>9s} {'mem_lo(s)':>9s} "
           f"{'mem_hi(s)':>9s} {'coll(s)':>9s} {'dominant':>10s} "
           f"{'useful':>7s} {'temp':>8s} {'fits':>5s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:22s} {r['shape']:12s} "
                         f"[{r['status']}] {r.get('reason','')}")
            continue
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['t_compute_s']:9.3f} "
            f"{r['t_memory_s']:9.3f} {r['t_memory_hi_s']:9.3f} "
            f"{r['t_collective_s']:9.3f} "
            f"{r['dominant']:>10s} {r['useful_ratio']:7.3f} "
            f"{r['temp_gib']:7.1f}G {str(r['fits_hbm']):>5s}")
    return "\n".join(lines)


def seed_autotune(tab=None, shapes=None, *, save: bool = True):
    """Seed MODEL-estimated autotune entries for shape buckets the measured
    sweep (bench_kernels) has not covered — the hardware-constant roofline
    estimate of the static flop model's pick, written with
    ``source="model"`` so any later measurement overrides it. This is the
    second half of the fleet pre-warm story: ship a table where every
    default bucket resolves *explicitly* (measured argmin where measured,
    materialized model fallback elsewhere) instead of re-deriving the
    fallback at trace time on a thousand workers."""
    from repro.core import ghost
    from repro.kernels import autotune, backend

    if tab is None:
        tab = autotune.load()
    if shapes is None:
        shapes = autotune.SWEEP_SHAPES_QUICK + autotune.SWEEP_SHAPES_FULL
    cfg = backend.EngineConfig(autotune=False)
    seeded = 0
    for b, t, din, dout in shapes:
        for op in autotune.OPS:
            if op == "paged_attn":
                continue  # gather-path cost is not flop-modeled
            if tab.lookup(op, t, din, dout):
                continue  # measured (or already-seeded) rows win
            choice = backend.choose_op(op, t, din, dout, cfg)
            flops = b * min(ghost.gram_path_cost(t, din, dout),
                            ghost.outer_path_cost(t, din, dout))
            est_us = max(flops / PEAK_FLOPS * 1e6, 0.01)
            if tab.record(op, t, din, dout, choice, est_us, source="model"):
                seeded += 1
    if save and seeded:
        try:
            tab.save()
        except OSError:
            pass
    return tab, seeded


def run(quick: bool = True) -> list[str]:
    from benchmarks.common import csv_line
    rows = table("single")
    lines = []
    tab, seeded = seed_autotune()
    lines.append(csv_line("roofline_autotune_seeded", 0.0,
                          f"model_buckets={seeded};table={tab.path}"))
    for r in rows:
        if r.get("status") != "ok":
            lines.append(csv_line(
                f"roofline_{r['arch']}_{r['shape']}", 0.0,
                f"status={r['status']}"))
            continue
        lines.append(csv_line(
            f"roofline_{r['arch']}_{r['shape']}", r["t_compute_s"] * 1e6,
            f"dom={r['dominant']};mem_s={r['t_memory_s']:.3f};"
            f"coll_s={r['t_collective_s']:.3f};"
            f"useful={r['useful_ratio']:.3f};fits={r['fits_hbm']}"))
    return lines


if __name__ == "__main__":
    print(format_table(table("single")))
