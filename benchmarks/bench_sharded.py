"""Sharded execution engine bench: step time + collective profile per mode.

The paper's Sec-4 claim is measured from the EXECUTING multi-device path
(shard_map train step, `repro.core.dp_sgd` with `mesh=`), not inferred from
a lowering: for each device count in (1, 4, 8) virtual CPU devices this
suite runs `per_layer`, `ghost_flat` and `per_group`-as-per-device on a
(data, model) mesh, records median step wall time, and classifies every
compiled collective by the mesh axes it crosses
(`launch.hlo_analysis.collective_axis_summary`). The headline columns:

  * `model_axis_norm_collectives` — MUST be 0 for per_group (per-device
    clipping is communication-free before scaling) and >= 1 for ghost_flat
    (the (B,) total-norm psum);
  * `by_axis` — norm traffic (model) vs grad traffic (data / data+model).

Each device count needs its own XLA device set, so the parent re-execs
itself as a `--child` subprocess with
`XLA_FLAGS=--xla_force_host_platform_device_count=N` before jax init.
Results land in ``benchmarks/BENCH_sharded.json`` (folded into
``BENCH_summary.json`` by ``benchmarks/run.py``).

Run:  PYTHONPATH=src python -m benchmarks.bench_sharded [--full|--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import time

_OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_sharded.json")
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

MODES = ("per_layer", "ghost_flat", "per_group")
# device count -> (data, model) mesh
MESHES = {1: (1, 1), 4: (2, 2), 8: (2, 4)}


def _child(devices: int, quick: bool) -> dict:
    """Measure all modes on THIS process's devices (exactly `devices`)."""
    import jax
    import jax.numpy as jnp

    from benchmarks.common import timeit, topology
    from repro import optim
    from repro.configs import get_config
    from repro.core.dp_sgd import DPConfig, make_dp_train_step
    from repro.core.spec import init_params
    from repro.launch.hlo_analysis import (classify_collectives,
                                           filter_model_norm_rows,
                                           summarize_axis_rows)
    from repro.launch.inputs import concrete_train_batch
    from repro.models.transformer import build_model

    assert jax.device_count() == devices, (jax.device_count(), devices)
    d, m = MESHES[devices]
    mesh = jax.make_mesh((d, m), ("data", "model"))
    cfg = get_config("tiny")
    model = build_model(cfg)
    params = init_params(model.spec, jax.random.PRNGKey(0))
    b, t = (8, 64) if quick else (16, 128)
    batch = concrete_train_batch(cfg, b, t, jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(2)

    records = []
    for mode in MODES:
        dpc = DPConfig(mode=mode, sigma=1.0, sampling_rate=0.01, steps=100,
                       adaptive=True, backend="xla")
        init_fn, step_fn, _ = make_dp_train_step(
            model.loss_fn, model.spec, model.layout, optim.adam(1e-3), dpc,
            batch_size=b, mesh=mesh)
        opt_state, dp_state = init_fn(params)
        step = jax.jit(step_fn)
        lowered = step.lower(params, opt_state, dp_state, batch, key)
        hlo = lowered.compile().as_text()
        us = timeit(step, params, opt_state, dp_state, batch, key,
                    warmup=1, iters=3 if quick else 5)
        rows = classify_collectives(hlo, mesh)  # parse the HLO once
        records.append({
            "mode": mode,
            "us_per_step": round(us, 1),
            "collectives_by_axis": summarize_axis_rows(rows),
            "model_axis_norm_collectives": sum(
                r["count"] for r in filter_model_norm_rows(rows)),
        })
    return {"device_count": devices, "mesh": f"{d}x{m}", "quick": quick,
            "batch": b, "seq": t, "topology": topology(),
            "records": records}


def run(quick: bool = True, device_counts=(1, 4, 8)) -> list[str]:
    """Parent: one subprocess per device count; writes BENCH_sharded.json."""
    from benchmarks.common import csv_line

    lines = []
    runs = {}
    for n in device_counts:
        env = dict(os.environ)
        flags = [f for f in env.get("XLA_FLAGS", "").split()
                 if "host_platform_device_count" not in f]
        env["XLA_FLAGS"] = " ".join(
            flags + [f"--xla_force_host_platform_device_count={n}"])
        env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
        cmd = [sys.executable, "-m", "benchmarks.bench_sharded", "--child",
               "--devices", str(n)] + ([] if quick else ["--full"])
        out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                             cwd=os.path.join(os.path.dirname(__file__), ".."),
                             timeout=1800)
        mm = re.search(r"CHILD_RESULT (.*)", out.stdout)
        if out.returncode != 0 or not mm:
            lines.append(csv_line(f"sharded_{n}dev_ERROR", 0.0,
                                  out.stderr.strip()[-200:].replace(",", ";")
                                  or "no output"))
            continue
        payload = json.loads(mm.group(1))
        runs[str(n)] = payload
        for r in payload["records"]:
            model_norm = r["model_axis_norm_collectives"]
            lines.append(csv_line(
                f"sharded_step_{r['mode']}_{n}dev", r["us_per_step"],
                f"mesh={payload['mesh']};"
                f"model_axis_norm_collectives={model_norm:g}"))
    data = {"runs": {}}
    if os.path.exists(_OUT_PATH):  # merge: a smoke run must not clobber
        try:                       # the full 1/4/8-device sweep
            prev = json.load(open(_OUT_PATH))
            if isinstance(prev.get("runs"), dict):
                data = prev
        except (OSError, ValueError):
            pass
    data.pop("quick", None)  # quick is per-run: a smoke refresh of one
    data["unix_time"] = int(time.time())  # device count must not relabel
    data["runs"].update(runs)             # the retained full-sweep records
    with open(_OUT_PATH, "w") as fh:
        json.dump(data, fh, indent=1)
    lines.append(csv_line("sharded_bench_json_written", 0.0, _OUT_PATH))
    return lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: 4-device run only")
    args = ap.parse_args()
    if args.child:
        payload = _child(args.devices, quick=not args.full)
        print("CHILD_RESULT " + json.dumps(payload), flush=True)
        return
    counts = (4,) if args.smoke else (1, 4, 8)
    for line in run(quick=not args.full, device_counts=counts):
        print(line, flush=True)


if __name__ == "__main__":
    main()
