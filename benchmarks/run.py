"""Benchmark orchestrator — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (harness contract).

Mapping to the paper:
  fig1_*       Figure 1   — per-update efficiency of clipping schemes
  table1_*     Tables 1/11, Figure 3 — fixed vs adaptive per-layer utility
  table4_*     Tables 4/12 — epoch-constrained adaptive-per-layer vs flat
  table6_*     Table 6 / Sec 4 — per-device clipping communication
  fig5/6_*     Figures 5/6, Table 10 — quantile & allocation ablations
  kernel_*     ghost-norm op microbenches (Sec 3.1 fused op)
  roofline_*   EXPERIMENTS.md §Roofline (from the multi-pod dry-run)
  serve_*      beyond-paper: slot-pool continuous-batching serving engine
               vs dispatch-per-token loops (occupancy + arrival sweeps)

Every suite that persists measurements writes a ``BENCH_*.json`` artifact
next to this file; after the suites run, ``aggregate()`` folds them all
into ``BENCH_summary.json`` so the perf trajectory across PRs is
machine-readable from ONE file (``--aggregate-only`` refreshes it without
re-benchmarking).

Run:  PYTHONPATH=src python -m benchmarks.run [--full] [--only PREFIX]
                                              [--aggregate-only]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time

_BENCH_DIR = os.path.dirname(os.path.abspath(__file__))
_SUMMARY_PATH = os.path.join(_BENCH_DIR, "BENCH_summary.json")


def aggregate() -> str:
    """Fold every BENCH_*.json artifact into BENCH_summary.json."""
    artifacts = {}
    for path in sorted(glob.glob(os.path.join(_BENCH_DIR, "BENCH_*.json"))):
        name = os.path.basename(path)
        if name == os.path.basename(_SUMMARY_PATH):
            continue
        try:
            with open(path) as fh:
                artifacts[name] = json.load(fh)
        except (OSError, ValueError) as e:
            artifacts[name] = {"error": f"{type(e).__name__}: {e}"}
    summary = {"unix_time": int(time.time()), "artifacts": artifacts}
    with open(_SUMMARY_PATH, "w") as fh:
        json.dump(summary, fh, indent=1)
    return _SUMMARY_PATH


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-size benches (slower)")
    ap.add_argument("--only", default=None,
                    help="run only benches whose module name contains this")
    ap.add_argument("--aggregate-only", action="store_true",
                    help="just rebuild BENCH_summary.json from existing "
                         "BENCH_*.json artifacts")
    args = ap.parse_args()
    quick = not args.full
    if args.aggregate_only:
        print(f"# wrote {aggregate()}", file=sys.stderr)
        return

    from benchmarks import (bench_epochs, bench_kernels, bench_quantile,
                            bench_scaling, bench_serve, bench_sharded,
                            bench_startup, bench_throughput, bench_utility,
                            roofline)
    suites = [
        ("throughput", bench_throughput),
        ("kernels", bench_kernels),
        ("startup", bench_startup),
        ("sharded", bench_sharded),
        ("serve", bench_serve),
        ("utility", bench_utility),
        ("epochs", bench_epochs),
        ("quantile", bench_quantile),
        ("scaling", bench_scaling),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in suites:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            for line in mod.run(quick=quick):
                print(line, flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}_SUITE_ERROR,0,{type(e).__name__}:{e}",
                  flush=True)
        print(f"# suite {name} took {time.time()-t0:.1f}s", file=sys.stderr)
    print(f"# wrote {aggregate()}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
