"""Serving throughput: slot-pool continuous batching vs dispatch loops.

Sweeps pool occupancy and arrival patterns over a ragged request set and
records tokens/sec into ``benchmarks/BENCH_serve.json`` (folded into
``BENCH_summary.json`` by ``benchmarks/run.py``).

All paths serve the SAME ragged request set and produce identical tokens
(tests/test_engine.py asserts the parity); only the scheduling differs:

  per_request_loop   greedy dispatch-per-token, one request at a time,
                     unpadded — the reference oracle, and the only
                     pre-engine path that was CORRECT on ragged traffic
                     (the padded static batch silently decoded from the
                     wrong position before this PR).
  padded_batch       the fixed padded batch: fused-scan prefill + one
                     dispatch per token for the whole batch. No admission
                     mid-flight — the batch must be known up front.
  engine_sN          launch.engine.DecodeEngine at pool size N, burst
                     arrivals (requests queue and recycle slots).
  engine_staggered   pool size 4 with arrivals trickling in mid-flight.

The budget sweep holds CACHE BYTES fixed instead of slot count: a
contiguous 2-slot engine sets the byte budget, then a paged engine is
sized to fit UNDER that budget (block pool + page tables + trash page)
and serves the same traffic — short requests only reserve the pages
they will actually touch, so the paged pool runs strictly more
concurrent slots on the same memory. ``paged_more_slots_at_budget`` in
BENCH_serve.json records the claim; ``--smoke`` asserts it.

Run:  PYTHONPATH=src python -m benchmarks.bench_serve [--smoke|--full]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import topology
from repro.configs import get_config
from repro.core.spec import init_params
from repro.launch.engine import DecodeEngine
from repro.launch.inputs import pad_ragged_prompts, synthetic_requests
from repro.launch.serve import greedy_decode
from repro.models.transformer import build_model

_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                    "BENCH_serve.json")


def _per_request_loop(model, params, step_fn, reqs, gen, cache_len):
    """Dispatch-per-token, per request, unpadded (shared compiled step)."""
    outs = []
    for r in reqs:
        cache = model.init_cache(1, cache_len)
        row = jnp.asarray(np.asarray(r, np.int32))[None, :]
        logits = None
        for t in range(row.shape[1]):
            logits, cache = step_fn(params, cache,
                                    {"token": row[:, t:t + 1]})
        tok = jnp.argmax(logits.astype(jnp.float32),
                         axis=-1)[:, None].astype(jnp.int32)
        toks = []
        for _ in range(gen):
            toks.append(tok)
            logits, cache = step_fn(params, cache, {"token": tok})
            tok = jnp.argmax(logits.astype(jnp.float32),
                             axis=-1)[:, None].astype(jnp.int32)
        outs.append(jnp.concatenate(toks, axis=1))
    jax.block_until_ready(outs)
    return outs


def _engine_serve(engine, reqs, gen, *, stagger_every=0):
    """Burst (stagger_every=0) or staggered mid-flight submission."""
    if not stagger_every:
        for r in reqs:
            engine.submit(r, max_new_tokens=gen)
        return engine.run()
    it = iter(reqs)
    engine.submit(next(it), max_new_tokens=gen)
    pending = True
    while pending or engine.num_live or engine.num_pending:
        for _ in range(stagger_every):
            engine.step()
        nxt = next(it, None)
        if nxt is None:
            pending = False
        else:
            engine.submit(nxt, max_new_tokens=gen)
    return engine.run()


def run(quick: bool = True):
    """Yield csv lines (harness contract) and write BENCH_serve.json."""
    cfg = get_config("tiny")
    model = build_model(cfg)
    params = init_params(model.spec, jax.random.PRNGKey(0))
    n_req = 8 if quick else 16
    gen = 16 if quick else 32
    min_len, max_len = 2, 12
    cache_len = max_len + gen + 8
    warm = synthetic_requests(cfg.vocab_size, 2, min_len=min_len,
                              max_len=max_len, seed=9)
    reqs = synthetic_requests(cfg.vocab_size, n_req, min_len=min_len,
                              max_len=max_len, seed=1)
    gen_tokens = n_req * gen
    record = {"config": {"arch": cfg.name, "n_requests": n_req, "gen": gen,
                         "prompt_lens": [int(len(r)) for r in reqs],
                         "cache_len": cache_len},
              "topology": topology(), "baselines": {}, "engine": {}}

    # ---- baseline: per-request dispatch-per-token loop ----
    step_fn = jax.jit(model.serve_step)
    _per_request_loop(model, params, step_fn, warm, 2, cache_len)  # compile
    t0 = time.perf_counter()
    _per_request_loop(model, params, step_fn, reqs, gen, cache_len)
    wall = time.perf_counter() - t0
    loop_tps = gen_tokens / wall
    record["baselines"]["per_request_loop"] = {
        "wall_s": wall, "tokens_per_s": loop_tps}
    yield f"serve_per_request_loop,{wall * 1e6:.1f},tok_s={loop_tps:.1f}"

    # ---- baseline: padded static batch, fused prefill ----
    prompts, lengths = pad_ragged_prompts(reqs)
    args = (model, params, jnp.asarray(prompts), gen, cache_len)
    kw = dict(prefill="fused", lengths=jnp.asarray(lengths))
    jax.block_until_ready(greedy_decode(*args, **kw))  # compile
    t0 = time.perf_counter()
    jax.block_until_ready(greedy_decode(*args, **kw))
    wall = time.perf_counter() - t0
    record["baselines"]["padded_batch"] = {
        "wall_s": wall, "tokens_per_s": gen_tokens / wall}
    yield (f"serve_padded_batch,{wall * 1e6:.1f},"
           f"tok_s={gen_tokens / wall:.1f}")

    # ---- engine: occupancy sweep (burst arrivals) ----
    slots_sweep = (1, 2, 4, 8, 16) if not quick else (1, 2, 4, 8)
    occupancy = []
    eng4 = None
    for s in slots_sweep:
        eng = DecodeEngine(model, params, num_slots=s, cache_len=cache_len)
        _engine_serve(eng, warm, 2)  # compile all three programs
        before = dict(eng.stats)  # dispatch counts for the TIMED run only
        t0 = time.perf_counter()
        _engine_serve(eng, reqs, gen)
        wall = time.perf_counter() - t0
        tps = gen_tokens / wall
        occupancy.append({"slots": s, "wall_s": wall, "tokens_per_s": tps,
                          "paged": eng.paged,
                          "cache_bytes": eng.cache_bytes(),
                          "decode_dispatches":
                              eng.stats["decode_dispatches"]
                              - before["decode_dispatches"],
                          "prefill_dispatches":
                              eng.stats["prefill_dispatches"]
                              - before["prefill_dispatches"],
                          "speedup_vs_loop": tps / loop_tps})
        if s == 4:
            eng4 = eng
        yield (f"serve_engine_s{s},{wall * 1e6:.1f},"
               f"tok_s={tps:.1f} vs_loop={tps / loop_tps:.2f}x")
    record["engine"]["occupancy"] = occupancy

    # ---- engine: staggered arrivals (mid-flight admission) ----
    t0 = time.perf_counter()
    _engine_serve(eng4, reqs, gen, stagger_every=3)
    wall = time.perf_counter() - t0
    tps = gen_tokens / wall
    record["engine"]["staggered_s4"] = {
        "wall_s": wall, "tokens_per_s": tps, "stagger_every_steps": 3}
    yield f"serve_engine_staggered_s4,{wall * 1e6:.1f},tok_s={tps:.1f}"

    # ---- paged vs contiguous at a fixed cache-byte budget ----
    # A contiguous 2-slot engine fixes the budget. The paged engine gets
    # one page-pool row-count LESS than those two contiguous slots (the
    # spare rows pay for the trash page and the int32 page tables) but
    # SIX slots over it: traffic of <=16-token requests holds 2 pages per
    # slot, so concurrency is bounded by the pool, not the slot count.
    b_gen = 6
    b_reqs = synthetic_requests(cfg.vocab_size, n_req, min_len=2,
                                max_len=10, seed=7)
    b_tokens = n_req * b_gen
    pl, b_cache = 8, 48
    ptab = b_cache // pl
    budget_sweep = []
    for label, kw in (
            ("contiguous_s2", dict(num_slots=2, paging="off")),
            ("paged_s6", dict(num_slots=6, paging="on", page_len=pl,
                              num_pages=2 * ptab - 2))):
        eng = DecodeEngine(model, params, cache_len=b_cache, **kw)
        _engine_serve(eng, warm, 2)  # compile
        # reset peak trackers so the warmup doesn't count
        eng.stats["peak_live_slots"] = 0
        t0 = time.perf_counter()
        _engine_serve(eng, b_reqs, b_gen)
        wall = time.perf_counter() - t0
        budget_sweep.append({
            "label": label, "slots": eng.num_slots, "paged": eng.paged,
            "cache_bytes": eng.cache_bytes(),
            "peak_live_slots": eng.stats["peak_live_slots"],
            "wall_s": wall, "tokens_per_s": b_tokens / wall})
        yield (f"serve_budget_{label},{wall * 1e6:.1f},"
               f"bytes={eng.cache_bytes()} "
               f"peak_live={eng.stats['peak_live_slots']} "
               f"tok_s={b_tokens / wall:.1f}")
    contig_b, paged_b = budget_sweep
    record["engine"]["budget_sweep"] = {
        "cache_len": b_cache, "page_len": pl, "gen": b_gen,
        "prompt_lens": [int(len(r)) for r in b_reqs],
        "entries": budget_sweep}
    record["paged_more_slots_at_budget"] = bool(
        paged_b["cache_bytes"] <= contig_b["cache_bytes"]
        and paged_b["peak_live_slots"] > contig_b["slots"])

    s4 = next(o for o in occupancy if o["slots"] == 4)
    record["engine_beats_loop_at_4"] = bool(
        s4["tokens_per_s"] > loop_tps)
    with open(_OUT, "w") as fh:
        json.dump(record, fh, indent=1)
    yield (f"serve_summary,0,engine_s4={s4['tokens_per_s']:.1f}tok_s "
           f"loop={loop_tps:.1f}tok_s "
           f"beats_loop={record['engine_beats_loop_at_4']} "
           f"paged_more_slots_at_budget="
           f"{record['paged_more_slots_at_budget']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="quick sizes (the scripts/bench_smoke.sh stage)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for line in run(quick=not args.full):
        print(line, flush=True)
    print(f"# wrote {_OUT}")
    if args.smoke:  # smoke asserts the acceptance bar, not just records it
        with open(_OUT) as fh:
            rec = json.load(fh)
        assert rec["engine_beats_loop_at_4"], (
            "engine at 4 slots did not beat the per-token dispatch loop")
        assert rec["paged_more_slots_at_budget"], (
            "paged engine did not serve more concurrent slots than the "
            "contiguous engine at the same cache-byte budget")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
