"""Kernel-level microbenchmarks: ghost-norm op vs naive materialization.

On CPU the Pallas kernels run in interpret mode (not representative), so
the timed comparison is between the XLA ghost path and the naive
per-example materialization — the paper's memory/time argument at op
granularity. The Pallas kernel itself is validated for correctness in
tests/ and characterized here by its ARITHMETIC footprint.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line, timeit
from repro.core import ghost


def run(quick: bool = True) -> list[str]:
    b, t, din, dout = (4, 512, 256, 256) if quick else (8, 2048, 1024, 1024)
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (b, t, din))
    g = jax.random.normal(jax.random.fold_in(key, 1), (b, t, dout)) * 0.1

    ghost_fn = jax.jit(lambda a, g: ghost.linear_norms_sq(a, g,
                                                          force_path="gram"))
    outer_fn = jax.jit(lambda a, g: ghost.linear_norms_sq(a, g,
                                                          force_path="outer"))

    def naive(a, g):
        pg = jnp.einsum("bti,bto->bio", a, g)  # materialize per-example
        return jnp.sum(pg**2, axis=(1, 2))

    naive_fn = jax.jit(naive)

    us_g = timeit(ghost_fn, a, g)
    us_o = timeit(outer_fn, a, g)
    us_n = timeit(naive_fn, a, g)
    gram_flops = b * t * t * (din + dout)
    outer_flops = b * t * din * dout
    lines = [
        csv_line("kernel_ghost_gram", us_g,
                 f"flops={gram_flops:.2e};mem=O(B*T*chunk)"),
        csv_line("kernel_ghost_outer", us_o,
                 f"flops={outer_flops:.2e};mem=O(B*din*dout)"),
        csv_line("kernel_naive_materialize", us_n,
                 f"flops={outer_flops:.2e};mem=O(B*din*dout)_PERSISTENT"),
    ]
    # clipped-sum fused op
    f = jax.random.uniform(jax.random.fold_in(key, 2), (b,))
    fused = jax.jit(ghost.clipped_sum_linear)
    us_f = timeit(fused, a, g, f)
    lines.append(csv_line("kernel_clip_reduce_xla", us_f,
                          f"flops={2*outer_flops:.2e}"))
    return lines
