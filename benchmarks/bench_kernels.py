"""Kernel-level microbenchmarks: ghost-op backends across (B, T, d).

Sweeps the backend engine (`repro.kernels.backend`) — xla reference paths
vs the Pallas kernels (ghost_norm / clip_reduce / fused_norm_clip) — over a
grid of shapes, plus the naive per-example materialization baseline. Writes
``benchmarks/BENCH_kernels.json`` so the perf trajectory is tracked across
PRs.

On CPU (this container) the Pallas kernels run in INTERPRET mode: their
timings are recorded with ``"representative": false`` and characterize
correctness cost only — the timed xla-vs-naive comparison is the paper's
memory/time argument at op granularity. On TPU the same sweep times the
compiled Mosaic kernels.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line, timeit, topology
from repro.kernels import backend

_OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_kernels.json")

# (B, T, din, dout) sweep — quick keeps interpret-mode cost tolerable
SHAPES_QUICK = [(4, 128, 128, 128), (4, 256, 256, 256)]
SHAPES_FULL = [(4, 512, 256, 256), (8, 1024, 512, 512), (8, 2048, 1024, 1024)]


def _bench_backend(name: str, shape, a, g, f, c, interpret_ok: bool,
                   records: list, lines: list):
    b, t, din, dout = shape
    tag = f"b{b}_t{t}_d{din}x{dout}"
    # no interpret override: on TPU the pallas ops time the compiled Mosaic
    # kernels; off-TPU the engine's default (interpret mode) applies and the
    # records are flagged non-representative
    eng = backend.make_engine(name)
    rep = name != "pallas" or jax.default_backend() == "tpu"
    if name == "pallas" and not interpret_ok:
        # no silent coverage gap: record WHY these rows are absent so the
        # cross-PR trajectory is distinguishable from an unswept backend
        records.append({"name": "kernel_pallas_skipped", "shape": tag,
                        "b": b, "t": t, "din": din, "dout": dout,
                        "backend": name,
                        "skipped": "interpret-mode too slow off-TPU"})
        lines.append(csv_line(f"kernel_pallas_skipped__{tag}", 0.0,
                              "interpret-mode too slow off-TPU"))
        return
    ops = {
        "norms": jax.jit(eng.linear_norms_sq),
        "clip_sum": jax.jit(eng.clipped_sum_linear),
        "linear_clip": jax.jit(eng.linear_clip),
    }
    args = {
        "norms": (a, g),
        "clip_sum": (a, g, f),
        "linear_clip": (a, g, c),
    }
    for op, fn in ops.items():
        us = timeit(fn, *args[op])
        rec = {
            "name": f"kernel_{op}_{name}", "shape": tag,
            "b": b, "t": t, "din": din, "dout": dout,
            "us_per_call": round(us, 1),
            "backend": name,
            "representative": rep,
        }
        if op == "norms":
            rec["auto_choice"] = backend.choose_linear_path(
                t, din, dout, eng.config)
        records.append(rec)
        lines.append(csv_line(f"kernel_{op}_{name}__{tag}", us,
                              f"backend={name};rep={rep}"))


def run(quick: bool = True) -> list[str]:
    shapes = SHAPES_QUICK if quick else SHAPES_QUICK + SHAPES_FULL
    key = jax.random.PRNGKey(0)
    lines: list[str] = []
    records: list[dict] = []
    for shape in shapes:
        b, t, din, dout = shape
        a = jax.random.normal(key, (b, t, din))
        g = jax.random.normal(jax.random.fold_in(key, 1), (b, t, dout)) * 0.1
        f = jax.random.uniform(jax.random.fold_in(key, 2), (b,))
        c = jnp.full((b,), 0.5)

        # naive per-example materialization baseline (the paper's Figure 1
        # "usual" clipping cost at op granularity)
        def naive(a, g):
            pg = jnp.einsum("bti,bto->bio", a, g)
            return jnp.sum(pg**2, axis=(1, 2))

        us_n = timeit(jax.jit(naive), a, g)
        tag = f"b{b}_t{t}_d{din}x{dout}"
        records.append({"name": "kernel_norms_naive", "shape": tag,
                        "b": b, "t": t, "din": din, "dout": dout,
                        "us_per_call": round(us_n, 1),
                        "backend": "naive", "representative": True})
        lines.append(csv_line(f"kernel_norms_naive__{tag}", us_n,
                              "mem=O(B*din*dout)_PERSISTENT"))

        # interpret-mode pallas on big shapes is minutes-slow; only sweep it
        # at the quick sizes (parity already covered in tests/)
        interpret_ok = (t * max(din, dout) <= 256 * 256
                        or jax.default_backend() == "tpu")
        for name in ("xla", "pallas"):
            _bench_backend(name, shape, a, g, f, c, interpret_ok,
                           records, lines)

    payload = {
        "topology": topology(),
        "unix_time": int(time.time()),
        "quick": quick,
        "records": records,
    }
    # keyed by mode so the common quick run never clobbers a saved full sweep
    data: dict = {"runs": {}}
    if os.path.exists(_OUT_PATH):
        try:
            prev = json.load(open(_OUT_PATH))
            if isinstance(prev.get("runs"), dict):
                data = prev
        except (OSError, ValueError):
            pass
    data["runs"]["quick" if quick else "full"] = payload
    with open(_OUT_PATH, "w") as fh:
        json.dump(data, fh, indent=1)
    lines.append(csv_line("kernel_bench_json_written", 0.0, _OUT_PATH))
    return lines
