"""Kernel-level microbenchmarks: ghost-op backends across (B, T, d).

Sweeps the backend engine (`repro.kernels.backend`) — xla reference paths
vs the Pallas kernels — over a grid of shapes for EVERY engine op the auto
backend dispatches on (norms / clip_sum / linear_clip / scale_contract /
paged_attn), plus the naive per-example materialization baseline. Writes
``benchmarks/BENCH_kernels.json`` so the perf trajectory is tracked across
PRs, and SEEDS the measured autotune table (`repro.kernels.autotune`) from
the timed records — this is how a fleet image ships with `auto` already
resolved to the measured argmin per (op, shape-bucket). Each record carries
two choice annotations:

  auto_choice        what `auto` picks AFTER this run's measurements are
                     seeded (the measured argmin for the record's bucket)
  auto_choice_model  what the static flop model alone would pick — the
                     unmeasured-bucket fallback, kept for comparison

On CPU (this container) the Pallas kernels run in INTERPRET mode: their
timings are recorded with ``"representative": false`` and characterize
correctness cost only — but they still seed the table for THIS topology
(the table is topology-stamped, so CPU measurements never leak to TPU; and
where interpret mode measured faster, it is faster). On TPU the same sweep
times the compiled Mosaic kernels.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line, timeit, topology
from repro.kernels import autotune, backend

_OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_kernels.json")

# (B, T, din, dout) sweep — quick keeps interpret-mode cost tolerable
SHAPES_QUICK = [(4, 128, 128, 128), (4, 256, 256, 256)]
SHAPES_FULL = [(4, 512, 256, 256), (8, 1024, 512, 512), (8, 2048, 1024, 1024)]


def _op_args(op: str, shape, a, g, f, c):
    """Operands per engine op; paged_attn/scale_contract come from the
    shared autotune helpers so the bench seeds the SAME buckets the engine
    looks up at trace time."""
    if op == "norms":
        return (a, g)
    if op == "clip_sum":
        return (a, g, f)
    if op == "linear_clip":
        return (a, g, c)
    if op == "scale_contract":
        return (jnp.stack([a, a * 0.5]), jnp.stack([g, g * 2.0]),
                jnp.stack([f, f]))
    if op == "paged_attn":
        return autotune.paged_attn_data(shape)
    raise ValueError(op)


def _op_fn(eng, op: str, shape):
    import functools
    if op == "paged_attn":
        _, _, din, _ = shape
        scale = 1.0 / (min(din, 64) ** 0.5)
        return jax.jit(functools.partial(eng.paged_attn, scale=scale))
    return jax.jit({
        "norms": eng.linear_norms_sq,
        "clip_sum": eng.clipped_sum_linear,
        "linear_clip": eng.linear_clip,
        "scale_contract": eng.scale_contract,
    }[op])


def _table_dims(op: str, shape):
    """(t, din, dout) table coordinates for one record."""
    b, t, din, dout = shape
    if op == "paged_attn":
        q, kp, vp, pt, _ = autotune.paged_attn_data(shape)
        return autotune.paged_attn_dims(q, pt, kp.shape[1], vp.shape[-1])
    return t, din, dout


def _bench_backend(name: str, shape, a, g, f, c, interpret_ok: bool,
                   records: list, lines: list):
    b, t, din, dout = shape
    tag = f"b{b}_t{t}_d{din}x{dout}"
    # no interpret override: on TPU the pallas ops time the compiled Mosaic
    # kernels; off-TPU the engine's default (interpret mode) applies and the
    # records are flagged non-representative
    eng = backend.make_engine(name)
    rep = name != "pallas" or jax.default_backend() == "tpu"
    if name == "pallas" and not interpret_ok:
        # no silent coverage gap: record WHY these rows are absent so the
        # cross-PR trajectory is distinguishable from an unswept backend
        records.append({"name": "kernel_pallas_skipped", "shape": tag,
                        "b": b, "t": t, "din": din, "dout": dout,
                        "backend": name,
                        "skipped": "interpret-mode too slow off-TPU"})
        lines.append(csv_line(f"kernel_pallas_skipped__{tag}", 0.0,
                              "interpret-mode too slow off-TPU"))
        return
    for op in autotune.OPS:
        fn = _op_fn(eng, op, shape)
        args = _op_args(op, shape, a, g, f, c)
        us = timeit(fn, *args)
        tt, tdi, tdo = _table_dims(op, shape)
        rec = {
            "name": f"kernel_{op}_{name}", "shape": tag,
            "b": b, "t": tt, "din": tdi, "dout": tdo,
            "us_per_call": round(us, 1),
            "backend": name,
            "representative": rep,
            # the static model's pick (the unmeasured-bucket fallback);
            # auto_choice (post-seeding measured argmin) is annotated after
            # the sweep in run()
            "auto_choice_model": backend.choose_op(
                op, tt, tdi, tdo,
                backend.EngineConfig(autotune=False)),
        }
        records.append(rec)
        lines.append(csv_line(f"kernel_{op}_{name}__{tag}", us,
                              f"backend={name};rep={rep}"))


def run(quick: bool = True) -> list[str]:
    shapes = SHAPES_QUICK if quick else SHAPES_QUICK + SHAPES_FULL
    key = jax.random.PRNGKey(0)
    lines: list[str] = []
    records: list[dict] = []
    for shape in shapes:
        b, t, din, dout = shape
        a = jax.random.normal(key, (b, t, din))
        g = jax.random.normal(jax.random.fold_in(key, 1), (b, t, dout)) * 0.1
        f = jax.random.uniform(jax.random.fold_in(key, 2), (b,))
        c = jnp.full((b,), 0.5)

        # naive per-example materialization baseline (the paper's Figure 1
        # "usual" clipping cost at op granularity)
        def naive(a, g):
            pg = jnp.einsum("bti,bto->bio", a, g)
            return jnp.sum(pg**2, axis=(1, 2))

        us_n = timeit(jax.jit(naive), a, g)
        tag = f"b{b}_t{t}_d{din}x{dout}"
        records.append({"name": "kernel_norms_naive", "shape": tag,
                        "b": b, "t": t, "din": din, "dout": dout,
                        "us_per_call": round(us_n, 1),
                        "backend": "naive", "representative": True})
        lines.append(csv_line(f"kernel_norms_naive__{tag}", us_n,
                              "mem=O(B*din*dout)_PERSISTENT"))

        # interpret-mode pallas on big shapes is minutes-slow; only sweep it
        # at the quick sizes (parity already covered in tests/)
        interpret_ok = (t * max(din, dout) <= 256 * 256
                        or jax.default_backend() == "tpu")
        for name in ("xla", "pallas"):
            _bench_backend(name, shape, a, g, f, c, interpret_ok,
                           records, lines)

    # seed the measured autotune table from this run, persist it, and
    # annotate every op record with the post-seeding choice — the measured
    # argmin that `auto` will now use on this topology
    table = autotune.seed_from_records(records)
    try:
        table.save()
        lines.append(csv_line("kernel_autotune_table_saved", 0.0,
                              f"{table.path};buckets={len(table)}"))
    except OSError as e:  # read-only checkout: the bench still reports
        lines.append(csv_line("kernel_autotune_table_saved", 0.0,
                              f"SKIPPED:{type(e).__name__}"))
    cfg = backend.EngineConfig()
    for rec in records:
        name = rec.get("name", "")
        if not name.startswith("kernel_") or "skipped" in name \
                or rec.get("backend") == "naive":
            continue
        op = name[len("kernel_"):-(len(rec["backend"]) + 1)]
        rec["auto_choice"] = backend.choose_op(
            op, rec["t"], rec["din"], rec["dout"], cfg, table=table)

    payload = {
        "topology": topology(),
        "unix_time": int(time.time()),
        "quick": quick,
        "autotune_table": table.path,
        "records": records,
    }
    # keyed by mode so the common quick run never clobbers a saved full sweep
    data: dict = {"runs": {}}
    if os.path.exists(_OUT_PATH):
        try:
            prev = json.load(open(_OUT_PATH))
            if isinstance(prev.get("runs"), dict):
                data = prev
        except (OSError, ValueError):
            pass
    data["runs"]["quick" if quick else "full"] = payload
    with open(_OUT_PATH, "w") as fh:
        json.dump(data, fh, indent=1)
    lines.append(csv_line("kernel_bench_json_written", 0.0, _OUT_PATH))
    return lines
