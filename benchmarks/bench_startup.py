"""Cold- vs warm-start wall time for the train and serve entry points.

PR 3 measured ~19s of retrace+compile for one production dryrun; every
train/serve/service worker pays its own version of that cold on startup.
This bench measures what the persistent compile cache
(`repro.launch.compile_cache`) buys back: each entry point runs as a REAL
subprocess twice against the same fresh cache root — the first run compiles
and serializes (cold), the second deserializes (warm) — and the full
process wall time (interpreter + imports + trace + compile/deserialize +
the actual steps) is recorded to ``benchmarks/BENCH_startup.json`` with the
topology stamp, folded into ``BENCH_summary.json`` by ``benchmarks/run``.

``python -m benchmarks.bench_startup --smoke`` ASSERTS the acceptance bar:
warm-start wall time strictly below cold-start for BOTH entry points
(scripts/bench_smoke.sh and CI run this).
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time

from benchmarks.common import csv_line, topology

_OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_startup.json")
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# small enough to finish in seconds, big enough that compile dominates the
# cold run (measured ~8.6s cold vs ~3.3s warm for train on the CPU container)
ENTRIES = {
    "train": ["-m", "repro.launch.train", "--arch", "tiny", "--steps", "2",
              "--batch", "8", "--seq", "32", "--docs", "64",
              "--log-every", "100"],
    "serve": ["-m", "repro.launch.serve", "--arch", "tiny", "--mode",
              "engine", "--batch", "2", "--slots", "2", "--prompt-len", "8",
              "--gen", "8"],
}


def _run_cli(argv: list[str], cache_root: str) -> float:
    env = dict(os.environ)
    env["PYTHONPATH"] = ("src" + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else "src")
    t0 = time.perf_counter()
    subprocess.run([sys.executable, *argv, "--cache-dir", cache_root],
                   cwd=_REPO, env=env, check=True,
                   stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    return time.perf_counter() - t0


def run(quick: bool = True) -> list[str]:
    lines: list[str] = []
    records: list[dict] = []
    root = tempfile.mkdtemp(prefix="repro_startup_")
    try:
        for entry, argv in ENTRIES.items():
            cache = os.path.join(root, entry)  # fresh root per entry = cold
            cold = _run_cli(argv, cache)
            warm = _run_cli(argv, cache)
            rec = {
                "name": f"startup_{entry}",
                "cold_s": round(cold, 3),
                "warm_s": round(warm, 3),
                "speedup": round(cold / warm, 2) if warm > 0 else None,
                "warm_faster": warm < cold,
            }
            records.append(rec)
            lines.append(csv_line(f"startup_{entry}_cold", cold * 1e6,
                                  "subprocess_wall"))
            lines.append(csv_line(f"startup_{entry}_warm", warm * 1e6,
                                  f"speedup={rec['speedup']};"
                                  f"warm_faster={rec['warm_faster']}"))
    finally:
        shutil.rmtree(root, ignore_errors=True)

    payload = {"topology": topology(), "unix_time": int(time.time()),
               "records": records}
    with open(_OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=1)
    lines.append(csv_line("startup_bench_json_written", 0.0, _OUT_PATH))
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="assert the acceptance bar: warm < cold for both "
                         "entry points")
    args = ap.parse_args(argv)
    for line in run(quick=True):
        print(line, flush=True)
    if args.smoke:
        with open(_OUT_PATH) as fh:
            recs = json.load(fh)["records"]
        bad = [r["name"] for r in recs if not r["warm_faster"]]
        if bad:
            print(f"SMOKE FAIL: warm start not faster for {bad}",
                  file=sys.stderr)
            return 1
        print(f"# startup smoke OK: "
              + ", ".join(f"{r['name']} {r['cold_s']}s->{r['warm_s']}s "
                          f"({r['speedup']}x)" for r in recs))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
